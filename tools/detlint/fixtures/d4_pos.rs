//@ zone: apps/pagerank.rs
//@ active: D4@8

pub struct Dummy;

impl Dummy {
    fn update(&self, ctx: &mut Ctx) {
        ctx.send(1, 2.0);
    }
}
