//@ zone: storage/hdfs.rs
//@ active:

pub fn guarded(x: Option<u32>) -> u32 {
    x.expect("index contract: key ranged from the map itself")
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_fine_in_tests() {
        assert_eq!(Some(1).unwrap(), 1);
    }
}
