//@ zone: ft/mod.rs
//@ active: W0@5, W0@6, W0@7

pub fn hygiene() {
    // detlint: allow(D9): no such rule
    // detlint: allow(D1):
    // detlint: allow(D1): nothing to suppress here
}
