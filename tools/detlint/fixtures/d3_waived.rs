//@ zone: ingest/mod.rs
//@ active:
//@ waived: D3@7

pub fn mean(xs: &[f64]) -> f64 {
    // detlint: allow(D3): diagnostics-only mean, result never hits state
    let s = xs.iter().copied().fold(0.0, |a, b| a + b);
    s / xs.len() as f64
}
