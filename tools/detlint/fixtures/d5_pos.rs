//@ zone: comm/mod.rs
//@ active: D5@5, D5@6

pub fn place(rank: usize, machines: usize, n_workers: usize) -> (usize, usize) {
    let m = rank % machines;
    let w = rank % n_workers;
    (m, w)
}
