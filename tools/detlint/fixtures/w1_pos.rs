//@ zone: ft/recovery_ops.rs
//@ active: W1@5, W1@6

pub fn risky(x: Option<u32>, y: Option<u32>) -> u32 {
    let a = x.unwrap();
    let b = y.expect("");
    a + b
}
