//@ zone: pregel/kernels.rs
//@ active:

pub fn lane_fold(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>()
}
