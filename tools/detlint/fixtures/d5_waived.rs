//@ zone: storage/pager.rs
//@ active:
//@ waived: D5@7

pub fn debug_spread(key: u64, machines: usize) -> usize {
    // detlint: allow(D5): debug histogram bucketing, not placement
    key as usize % machines
}
