//@ zone: metrics/report.rs
//@ active:
//@ waived: D2@7

pub fn report_header_age() -> u64 {
    // detlint: allow(D2): one-shot header timestamp, never fed back
    let now = std::time::SystemTime::now();
    now.elapsed().map(|d| d.as_secs()).unwrap_or(0)
}
