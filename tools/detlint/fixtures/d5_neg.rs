//@ zone: graph/partition.rs
//@ active:

pub fn rank_of(v: u64, n_workers: usize) -> usize {
    (v as usize) % n_workers
}
