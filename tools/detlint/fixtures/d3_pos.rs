//@ zone: pregel/worker.rs
//@ active: D3@5, D3@6, D3@7

pub fn bad_reductions(xs: &[f32], ts: &[f64]) -> (f32, f64, f32) {
    let a = xs.iter().sum::<f32>();
    let b = ts.iter().copied().fold(0.0, f64::max);
    let c = xs.iter().fold(1.0f32, |m, &x| m * x);
    (a, b, c)
}
