//@ zone: obs/chrome.rs
//@ active: D1@4, D1@7

use std::collections::HashMap;

pub fn lanes(events: &[(u32, u32)]) -> usize {
    let m: HashMap<u32, u32> = events.iter().copied().collect();
    m.len()
}
