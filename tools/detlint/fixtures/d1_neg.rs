//@ zone: pregel/engine.rs
//@ active:

use std::collections::BTreeMap;

/// A HashMap would be wrong here (comment only).
pub fn count(xs: &[u64]) -> usize {
    let m: BTreeMap<u64, u64> = xs.iter().map(|&x| (x, 1)).collect();
    let label = "HashMap in a string is fine";
    m.len() + label.len()
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn hash_ok_in_tests() {
        assert!(HashMap::<u64, u64>::new().is_empty());
    }
}
