//@ zone: apps/sssp.rs
//@ active:

impl Dummy {
    fn update(&self, ctx: &mut Ctx) {
        ctx.set_value(1.0);
    }

    fn emit(&self, ctx: &mut Ctx) {
        ctx.send(1, 2.0);
        ctx.send_all(3.0);
    }
}
