//@ zone: sim/clock.rs
//@ active:

pub struct WallTimer {
    start: std::time::Instant,
}

impl WallTimer {
    pub fn start() -> Self {
        WallTimer { start: std::time::Instant::now() }
    }
}
