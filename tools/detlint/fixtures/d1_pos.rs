//@ zone: pregel/engine.rs
//@ active: D1@4, D1@7

use std::collections::HashMap;

pub fn count(xs: &[u64]) -> usize {
    let m: HashMap<u64, u64> = xs.iter().map(|&x| (x, 1)).collect();
    m.len()
}
