//! Diagnostics: what a rule reports, and how it renders.

/// Diagnostic severity. `Error` rules guard bit-identity contracts and
/// always fail the lint; `Warning` rules (W1) guard attributability and
/// fail only under `--deny-warnings` (which CI and the tier-1 test
/// pass).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

impl Severity {
    pub fn label(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Rule id: `D1`..`D5`, `W1`, or `W0` (waiver hygiene).
    pub rule: &'static str,
    pub severity: Severity,
    /// Path relative to the scanned root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// What went wrong and what to do instead.
    pub message: String,
    /// The offending raw source line, trimmed.
    pub excerpt: String,
}

impl Diagnostic {
    /// `file:line: severity[rule]: message` plus an excerpt line.
    pub fn render(&self) -> String {
        let mut excerpt = self.excerpt.clone();
        if excerpt.len() > 120 {
            excerpt.truncate(117);
            excerpt.push_str("...");
        }
        format!(
            "{}:{}: {}[{}]: {}\n    | {}",
            self.file,
            self.line,
            self.severity.label(),
            self.rule,
            self.message,
            excerpt
        )
    }
}

/// Stable ordering for reports: by file, then line, then rule.
pub fn sort_diagnostics(diags: &mut [Diagnostic]) {
    diags.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_is_greppable() {
        let d = Diagnostic {
            rule: "D1",
            severity: Severity::Error,
            file: "pregel/engine.rs".to_string(),
            line: 7,
            message: "no hash-ordered containers".to_string(),
            excerpt: "let m = HashMap::new();".to_string(),
        };
        let r = d.render();
        assert!(r.starts_with("pregel/engine.rs:7: error[D1]:"));
        assert!(r.contains("HashMap::new()"));
    }

    #[test]
    fn sort_orders_by_file_then_line() {
        let mk = |file: &str, line: usize| Diagnostic {
            rule: "D2",
            severity: Severity::Error,
            file: file.to_string(),
            line,
            message: String::new(),
            excerpt: String::new(),
        };
        let mut v = vec![mk("b.rs", 1), mk("a.rs", 9), mk("a.rs", 2)];
        sort_diagnostics(&mut v);
        assert_eq!(v[0].file, "a.rs");
        assert_eq!(v[0].line, 2);
        assert_eq!(v[2].file, "b.rs");
    }
}
