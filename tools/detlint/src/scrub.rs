//! Comment- and string-aware source scrubbing, plus brace-tracked item
//! regions.
//!
//! detlint has no dependencies, so instead of a full parser it uses the
//! classic lexical trick: produce a *scrubbed* copy of the source in
//! which every comment, string literal and char literal is blanked to
//! spaces (newlines preserved), aligned line-for-line with the
//! original. Rule patterns then scan the scrubbed lines — a `HashMap`
//! mentioned in a doc comment or an `"Instant::now"` inside a format
//! string can never trip a rule — while waiver comments are parsed from
//! the raw lines.
//!
//! Handled lexical forms: `//` line comments, nested `/* */` block
//! comments, `"…"` strings with escapes, byte strings `b"…"`, raw
//! strings `r"…"` / `r#"…"#` / `br##"…"##` (any hash depth), char
//! literals (`'a'`, `'\n'`, `'\u{1F600}'`), and lifetimes (`'a`, `'_`)
//! which are *not* char literals.

/// A scrubbed source file: `lines[i]` is the sanitized form of
/// `raw_lines[i]`.
pub struct Scrubbed {
    /// Sanitized lines: comments and literal contents blanked.
    pub lines: Vec<String>,
    /// Original lines (waiver comments, diagnostic excerpts).
    pub raw_lines: Vec<String>,
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Push `c` to `out`, preserving newlines, blanking everything else.
fn push_blank(out: &mut String, c: char) {
    out.push(if c == '\n' { '\n' } else { ' ' });
}

/// If `chars[i..]` starts a raw string (`r`, `r#`, `br##`, …), return
/// `(hash_count, index_of_opening_quote)`.
fn raw_string_start(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j))
    } else {
        None
    }
}

/// Does `chars[i..]` start with `count` consecutive `#`s?
fn has_hashes(chars: &[char], i: usize, count: usize) -> bool {
    (0..count).all(|k| chars.get(i + k) == Some(&'#'))
}

/// Scrub `src` (see module docs).
pub fn scrub(src: &str) -> Scrubbed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(src.len());
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        // Line comment: blank to end of line.
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            while i < n && chars[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // Block comment, nested (Rust allows /* /* */ */).
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            out.push_str("  ");
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    out.push_str("  ");
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                } else {
                    push_blank(&mut out, chars[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw (byte) string: no escapes, closes at `"` + matching #s.
        if (c == 'r' || c == 'b') && (i == 0 || !is_ident_char(chars[i - 1])) {
            if let Some((hashes, quote)) = raw_string_start(&chars, i) {
                for _ in i..quote {
                    out.push(' ');
                }
                out.push('"');
                i = quote + 1;
                while i < n {
                    if chars[i] == '"' && has_hashes(&chars, i + 1, hashes) {
                        out.push('"');
                        for _ in 0..hashes {
                            out.push(' ');
                        }
                        i += 1 + hashes;
                        break;
                    }
                    push_blank(&mut out, chars[i]);
                    i += 1;
                }
                continue;
            }
        }
        // Byte string b"…": escape-aware like a normal string.
        let starts_string = c == '"'
            || (c == 'b'
                && (i == 0 || !is_ident_char(chars[i - 1]))
                && chars.get(i + 1) == Some(&'"'));
        if starts_string {
            if c == 'b' {
                out.push(' ');
                i += 1;
            }
            out.push('"');
            i += 1;
            while i < n {
                if chars[i] == '\\' && i + 1 < n {
                    push_blank(&mut out, chars[i]);
                    push_blank(&mut out, chars[i + 1]);
                    i += 2;
                    continue;
                }
                if chars[i] == '"' {
                    out.push('"');
                    i += 1;
                    break;
                }
                push_blank(&mut out, chars[i]);
                i += 1;
            }
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if chars.get(i + 1) == Some(&'\\') {
                // Escaped char literal: '\n', '\\', '\'', '\u{1F600}'.
                out.push('\'');
                out.push(' '); // the backslash
                i += 2;
                if i < n {
                    // The escaped character itself (possibly a quote).
                    push_blank(&mut out, chars[i]);
                    i += 1;
                }
                while i < n && chars[i] != '\'' {
                    push_blank(&mut out, chars[i]);
                    i += 1;
                }
                if i < n {
                    out.push('\'');
                    i += 1;
                }
                continue;
            }
            if chars.get(i + 2) == Some(&'\'') && chars.get(i + 1) != Some(&'\'') {
                // Simple char literal 'x' (including '_' and unicode).
                out.push('\'');
                out.push(' ');
                out.push('\'');
                i += 3;
                continue;
            }
            // Lifetime tick: pass through.
            out.push('\'');
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    Scrubbed {
        lines: out.lines().map(String::from).collect(),
        raw_lines: src.lines().map(String::from).collect(),
    }
}

/// Per-line mask of brace-delimited regions opened after a trigger
/// line: `mask[i]` is true from the trigger line through the line
/// closing the first `{` that follows it. Used for `#[cfg(test)]`
/// modules and `fn update` bodies. Regions do not nest — a trigger
/// inside an open region is ignored.
pub fn region_mask(lines: &[String], trigger: impl Fn(&str) -> bool) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut depth = 0usize;
    let mut close_at: Option<usize> = None;
    let mut pending = false;
    for (idx, line) in lines.iter().enumerate() {
        if close_at.is_some() {
            mask[idx] = true;
        }
        if close_at.is_none() && !pending && trigger(line) {
            pending = true;
        }
        for ch in line.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if pending {
                        close_at = Some(depth);
                        pending = false;
                        mask[idx] = true;
                    }
                }
                '}' => {
                    if close_at == Some(depth) {
                        close_at = None;
                    }
                    depth = depth.saturating_sub(1);
                }
                _ => {}
            }
        }
        if pending {
            // Between the trigger and its opening brace (attribute
            // line, multi-line signature).
            mask[idx] = true;
        }
    }
    mask
}

/// Lines inside `#[cfg(test)]`-gated items. Contracts govern runtime
/// code; tests assert on it and may use whatever they like.
pub fn test_mask(lines: &[String]) -> Vec<bool> {
    region_mask(lines, |l| l.contains("#[cfg(test)]"))
}

/// Lines inside `fn update` bodies (the two-phase vertex API's
/// state-fold half — rule D4).
pub fn update_fn_mask(lines: &[String]) -> Vec<bool> {
    region_mask(lines, |l| {
        if let Some(pos) = l.find("fn update") {
            let rest = &l[pos + "fn update".len()..];
            let next = rest.trim_start().chars().next();
            matches!(next, Some('(') | Some('<'))
        } else {
            false
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scrub_lines(src: &str) -> Vec<String> {
        scrub(src).lines
    }

    #[test]
    fn comments_are_blanked() {
        let l = scrub_lines("let x = 1; // HashMap here\n/* Instant::now */ let y = 2;");
        assert_eq!(l[0].trim_end(), "let x = 1;");
        assert!(!l[0].contains("HashMap"));
        assert!(!l[1].contains("Instant"));
        assert!(l[1].contains("let y = 2;"));
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let l = scrub_lines("/* outer /* inner */ still comment */ let z = 3;");
        assert!(!l[0].contains("inner"));
        assert!(l[0].contains("let z = 3;"));
    }

    #[test]
    fn string_contents_are_blanked_but_quotes_survive() {
        let l = scrub_lines(r#"let s = "Instant::now \" escaped"; let t = 1;"#);
        assert!(!l[0].contains("Instant"));
        assert!(l[0].contains("let t = 1;"));
        assert_eq!(l[0].matches('"').count(), 2);
    }

    #[test]
    fn raw_strings_and_byte_strings_are_blanked() {
        let l = scrub_lines("let a = r#\"HashMap \"quoted\" inside\"#; let b = b\"SystemTime\";");
        assert!(!l[0].contains("HashMap"));
        assert!(!l[0].contains("SystemTime"));
        assert!(l[0].contains("let b ="));
    }

    #[test]
    fn char_literals_blank_but_lifetimes_survive() {
        let l = scrub_lines("fn f<'a>(x: &'a str) -> char { let c = 'x'; let q = '\\''; c }");
        assert!(l[0].contains("<'a>"));
        assert!(l[0].contains("&'a str"));
        assert!(!l[0].contains("'x'"));
    }

    #[test]
    fn multiline_strings_keep_line_alignment() {
        let src = "let s = \"line one\nHashMap in string\nlast\"; let after = 1;";
        let l = scrub_lines(src);
        assert_eq!(l.len(), 3);
        assert!(!l[1].contains("HashMap"));
        assert!(l[2].contains("let after = 1;"));
    }

    #[test]
    fn test_mask_covers_cfg_test_module() {
        let src = "fn real() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}";
        let sc = scrub(src);
        let mask = test_mask(&sc.lines);
        assert_eq!(mask, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn update_fn_mask_covers_only_update_body() {
        let src = "fn update(&self, ctx: &mut C) {\n    body();\n}\nfn emit(&self) {\n    e();\n}";
        let sc = scrub(src);
        let mask = update_fn_mask(&sc.lines);
        assert_eq!(mask, vec![true, true, true, false, false, false]);
    }

    #[test]
    fn update_fn_mask_ignores_lookalike_names() {
        let src = "fn update_ctx(&self) {\n    body();\n}";
        let sc = scrub(src);
        let mask = update_fn_mask(&sc.lines);
        assert_eq!(mask, vec![false, false]);
    }
}
