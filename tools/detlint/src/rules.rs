//! The determinism-contract rules (DESIGN.md §10).
//!
//! Every rule is zone-scoped by path prefix relative to the scanned
//! root (`rust/src`), operates on scrubbed lines (comments and literal
//! contents blanked — see [`crate::scrub`]), and skips `#[cfg(test)]`
//! regions: the contracts govern runtime code, tests assert on it.

use crate::diag::{Diagnostic, Severity};
use crate::scrub::{test_mask, update_fn_mask, Scrubbed};

/// Rule ids that participate in the waiver baseline, in report order.
pub const BASELINE_RULES: [&str; 6] = ["D1", "D2", "D3", "D4", "D5", "W1"];

/// Deterministic zones for D1: every module whose iteration order can
/// reach vertex state, wire bytes, checkpoint blobs, placement, or the
/// printed report.
const D1_ZONES: [&str; 10] = [
    "pregel/",
    "ft/",
    "storage/",
    "ingest/",
    "graph/",
    "comm/",
    "runtime/",
    "coordinator/",
    "metrics/",
    "obs/",
];

/// D2 applies everywhere except the two sanctioned homes.
const D2_EXEMPT: [&str; 2] = ["sim/clock.rs", "util/rng.rs"];

/// D3 applies everywhere except the canonical fold homes: the lane-tree
/// kernels (DESIGN.md §5 rule 4 / §5a) and the clock-time reductions
/// (`sim::clock::max_time`, order-independent `f64::max`).
const D3_EXEMPT: [&str; 2] = ["pregel/kernels.rs", "sim/clock.rs"];

const D4_ZONES: [&str; 1] = ["apps/"];

/// D5 applies everywhere except the canonical placement helpers.
const D5_EXEMPT: [&str; 2] = ["sim/cost.rs", "graph/partition.rs"];

const W1_ZONES: [&str; 3] = ["ft/", "storage/", "ingest/"];

/// One-paragraph contract documentation per rule (`detlint --explain`).
pub fn rule_doc(rule: &str) -> Option<&'static str> {
    match rule {
        "D1" => Some(
            "D1 — no hash-ordered containers in deterministic zones. \
             HashMap/HashSet iteration order varies per process, so any use \
             inside pregel/, ft/, storage/, ingest/, graph/, comm/, runtime/, \
             coordinator/, metrics/ or obs/ can leak nondeterministic order into \
             wire batches, checkpoint blobs or the report (DESIGN.md §5 \
             merge-order contract, §6a slot-major streams). Use BTreeMap / \
             BTreeSet or a sorted Vec; waive only when order provably cannot \
             escape (membership-only sets).",
        ),
        "D2" => Some(
            "D2 — no ambient wall-clock or entropy sources. Instant::now, \
             SystemTime and thread_rng make reruns incomparable and replay \
             non-reproducible. Virtual time comes from sim::clock::Clock; \
             wall-clock for *reporting only* goes through \
             sim::clock::WallTimer; randomness through util::Rng (seeded \
             splitmix64/xoshiro256**).",
        ),
        "D3" => Some(
            "D3 — no open-coded floating-point reductions. Float folds are \
             order-sensitive; every per-slot fold must route through the \
             canonical lane-tree kernels (pregel::kernels::sum_f32 / min_f32, \
             DESIGN.md §5 rule 4, §5a) so N-thread and SIMD runs stay \
             bit-identical. Clock-time maxima belong in sim::clock::max_time.",
        ),
        "D4" => Some(
            "D4 — no sends inside `fn update`. The two-phase vertex API \
             (update = state fold, emit = message generation) is what makes \
             replay emit-only and recovery bit-identical (DESIGN.md §4); a \
             send-shaped call inside an update body breaks the phase split \
             even if it compiles against some helper type.",
        ),
        "D5" => Some(
            "D5 — placement arithmetic only via the canonical helpers. \
             `% machines` / `% workers` open-coded at a use site can drift \
             from the static-placement recovery invariant (rank_of, \
             Topology::machine_of — DESIGN.md §3a): respawned workers keep \
             their rank precisely because every placement decision goes \
             through one function.",
        ),
        "W1" => Some(
            "W1 (warn) — unwrap() and expect(\"\") on the checkpoint-commit, \
             recovery and ingest paths must carry a contract-stating message, \
             so a panic in the flush lane is attributable to the invariant \
             that broke (executor panics re-raise with phase name + rank; an \
             anonymous unwrap defeats that).",
        ),
        "W0" => Some(
            "W0 — waiver hygiene. `// detlint: allow(RULE): justification` \
             must name a known rule and carry a non-empty justification, and \
             must actually suppress a violation on its own or the next line; \
             stale waivers are errors so the waiver count only moves with \
             intent (the checked-in baseline pins it).",
        ),
        _ => None,
    }
}

fn in_any(relpath: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| relpath.starts_with(p))
}

/// First occurrence of `word` in `line` as a whole identifier
/// (ASCII-boundary check on both sides), starting at `from`.
fn find_word_from(line: &str, word: &str, from: usize) -> Option<usize> {
    let bytes = line.as_bytes();
    let mut start = from;
    while let Some(pos) = line[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let after = at + word.len();
        let after_ok = after >= bytes.len() || !is_ident_byte(bytes[after]);
        if before_ok && after_ok {
            return Some(at);
        }
        start = at + word.len();
    }
    None
}

fn contains_word(line: &str, word: &str) -> bool {
    find_word_from(line, word, 0).is_some()
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Does `line` contain a `.fold(` whose initial accumulator is
/// float-typed (a float literal or an `f32::`/`f64::` constant)?
fn has_float_fold(line: &str) -> bool {
    let mut from = 0usize;
    while let Some(pos) = line[from..].find(".fold(") {
        let arg = line[from + pos + ".fold(".len()..].trim_start();
        if arg.starts_with("f32::") || arg.starts_with("f64::") {
            return true;
        }
        if starts_with_float_literal(arg) {
            return true;
        }
        from += pos + ".fold(".len();
    }
    false
}

/// `0.0`, `1.5e3`, `0.0f32` — digits, a dot, then a digit.
fn starts_with_float_literal(s: &str) -> bool {
    let bytes = s.as_bytes();
    let mut i = 0usize;
    while i < bytes.len() && (bytes[i].is_ascii_digit() || bytes[i] == b'_') {
        i += 1;
    }
    i > 0 && i + 1 < bytes.len() && bytes[i] == b'.' && bytes[i + 1].is_ascii_digit()
}

/// Does `line` use `%` against an operand that names cluster shape
/// (`machines`, `workers`, `n_workers`, `workers_per_machine`)?
fn has_placement_modulo(line: &str) -> Option<String> {
    let bytes = line.as_bytes();
    for (i, &b) in bytes.iter().enumerate() {
        if b != b'%' {
            continue;
        }
        let rest = line[i + 1..].trim_start();
        let operand: String = rest
            .chars()
            .take_while(|c| c.is_alphanumeric() || matches!(c, '_' | '.' | ':'))
            .collect();
        if operand.contains("machine") || operand.contains("worker") {
            return Some(operand);
        }
    }
    None
}

fn diag(
    rule: &'static str,
    severity: Severity,
    relpath: &str,
    lineno: usize,
    raw_line: &str,
    message: String,
) -> Diagnostic {
    Diagnostic {
        rule,
        severity,
        file: relpath.to_string(),
        line: lineno,
        message,
        excerpt: raw_line.trim().to_string(),
    }
}

/// Run every rule over one scrubbed file. `relpath` is the
/// `/`-separated path relative to the scanned root; it decides which
/// zones apply. Returns raw (pre-waiver) diagnostics in line order.
pub fn check_file(relpath: &str, sc: &Scrubbed) -> Vec<Diagnostic> {
    let tests = test_mask(&sc.lines);
    let d4_applies = in_any(relpath, &D4_ZONES);
    let update_body = if d4_applies {
        update_fn_mask(&sc.lines)
    } else {
        Vec::new()
    };
    let d1_applies = in_any(relpath, &D1_ZONES);
    let d2_applies = !in_any(relpath, &D2_EXEMPT);
    let d3_applies = !in_any(relpath, &D3_EXEMPT);
    let d5_applies = !in_any(relpath, &D5_EXEMPT);
    let w1_applies = in_any(relpath, &W1_ZONES);

    let mut out = Vec::new();
    for (idx, line) in sc.lines.iter().enumerate() {
        if tests.get(idx).copied().unwrap_or(false) {
            continue;
        }
        let lineno = idx + 1;
        let raw = sc.raw_lines.get(idx).map(String::as_str).unwrap_or("");

        if d1_applies {
            for word in ["HashMap", "HashSet"] {
                if contains_word(line, word) {
                    out.push(diag(
                        "D1",
                        Severity::Error,
                        relpath,
                        lineno,
                        raw,
                        format!(
                            "{word} in a deterministic zone: iteration order is \
                             per-process nondeterministic (DESIGN.md §5); use \
                             BTree{} or a sorted Vec",
                            &word[4..]
                        ),
                    ));
                }
            }
        }

        if d2_applies {
            for word in ["Instant", "SystemTime", "thread_rng"] {
                if contains_word(line, word) {
                    out.push(diag(
                        "D2",
                        Severity::Error,
                        relpath,
                        lineno,
                        raw,
                        format!(
                            "{word} is an ambient wall-clock/entropy source; use \
                             sim::clock::WallTimer (reporting) or util::Rng \
                             (randomness)"
                        ),
                    ));
                }
            }
            if let Some(pos) = find_word_from(line, "rand", 0) {
                if line[pos + 4..].starts_with("::") {
                    out.push(diag(
                        "D2",
                        Severity::Error,
                        relpath,
                        lineno,
                        raw,
                        "the rand crate is a nondeterministic entropy source; use \
                         util::Rng (seeded xoshiro256**)"
                            .to_string(),
                    ));
                }
            }
        }

        if d3_applies {
            let sum = line.contains(".sum::<f32>") || line.contains(".sum::<f64>");
            if sum || has_float_fold(line) {
                out.push(diag(
                    "D3",
                    Severity::Error,
                    relpath,
                    lineno,
                    raw,
                    "open-coded floating-point reduction: float folds are \
                     order-sensitive; route through pregel::kernels (per-slot \
                     folds, §5 rule 4) or sim::clock::max_time (clock maxima)"
                        .to_string(),
                ));
            }
        }

        if d4_applies && update_body.get(idx).copied().unwrap_or(false) {
            for pat in [".send(", ".send_all(", ".send_to("] {
                if line.contains(pat) {
                    out.push(diag(
                        "D4",
                        Severity::Error,
                        relpath,
                        lineno,
                        raw,
                        "send-shaped call inside `fn update`: the two-phase API \
                         keeps updates send-free so replay is emit-only and \
                         recovery bit-identical (DESIGN.md §4); move the send \
                         into `emit`/`respond`"
                            .to_string(),
                    ));
                    break;
                }
            }
        }

        if d5_applies {
            if let Some(operand) = has_placement_modulo(line) {
                out.push(diag(
                    "D5",
                    Severity::Error,
                    relpath,
                    lineno,
                    raw,
                    format!(
                        "open-coded placement arithmetic `% {operand}`: static \
                         placement must go through Partitioner::rank_of / \
                         Topology::machine_of (DESIGN.md §3a) so recovery \
                         reproduces it"
                    ),
                ));
            }
        }

        if w1_applies {
            let bare_unwrap = line.contains(".unwrap()");
            let empty_expect = line.contains(".expect(\"\")");
            if bare_unwrap || empty_expect {
                out.push(diag(
                    "W1",
                    Severity::Warning,
                    relpath,
                    lineno,
                    raw,
                    "bare unwrap/expect on a checkpoint/recovery/ingest path: \
                     state the violated contract in an expect(...) message (or \
                     propagate the error) so flush-lane panics stay attributable"
                        .to_string(),
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scrub::scrub;

    fn run(relpath: &str, src: &str) -> Vec<Diagnostic> {
        check_file(relpath, &scrub(src))
    }

    #[test]
    fn d1_flags_hash_containers_only_in_zones() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(run("pregel/engine.rs", src).len(), 1);
        assert_eq!(run("sim/cost.rs", src).len(), 0);
    }

    #[test]
    fn d1_ignores_comments_strings_and_tests() {
        let src = "// a HashMap in prose\nlet s = \"HashMap\";\n#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n}\n";
        assert!(run("ft/mod.rs", src).is_empty());
    }

    #[test]
    fn d2_flags_clock_sources_everywhere_but_exempt_files() {
        let src = "let t = std::time::Instant::now();\n";
        assert_eq!(run("apps/pagerank.rs", src).len(), 1);
        assert_eq!(run("sim/clock.rs", src).len(), 0);
        assert_eq!(run("util/rng.rs", "use rand::thread_rng;\n").len(), 0);
    }

    #[test]
    fn d2_word_boundaries_do_not_misfire() {
        // `instant` lowercase, `operand::x` — no rule words.
        let src = "let instant = 3; let x = operand::new();\n";
        assert!(run("pregel/worker.rs", src).is_empty());
    }

    #[test]
    fn d3_flags_float_reductions_but_not_integer_folds() {
        assert_eq!(run("apps/sssp.rs", "let s = xs.iter().sum::<f32>();\n").len(), 1);
        assert_eq!(run("ft/mod.rs", "let m = t.fold(0.0, f64::max);\n").len(), 1);
        assert_eq!(run("ft/mod.rs", "let m = t.fold(f32::INFINITY, f32::min);\n").len(), 1);
        assert!(run("ft/mod.rs", "let c = xs.iter().fold(0, |a, _| a + 1);\n").is_empty());
        assert!(run("pregel/kernels.rs", "let s = xs.iter().sum::<f32>();\n").is_empty());
        assert!(run("sim/clock.rs", "let m = t.fold(0.0f64, f64::max);\n").is_empty());
    }

    #[test]
    fn d4_flags_sends_in_update_but_not_emit() {
        let src = "fn update(&self, ctx: &mut C) {\n    ctx.send(1, m);\n}\nfn emit(&self, ctx: &mut E) {\n    ctx.send(1, m);\n}\n";
        let diags = run("apps/pagerank.rs", src);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].line, 2);
    }

    #[test]
    fn d5_flags_open_coded_placement_modulo() {
        assert_eq!(run("pregel/message.rs", "let m = rank % machines;\n").len(), 1);
        assert_eq!(run("comm/ulfm.rs", "let m = r % self.n_workers;\n").len(), 1);
        assert!(run("sim/cost.rs", "let m = rank % self.machines;\n").is_empty());
        assert!(run("pregel/engine.rs", "let k = step % cp_every;\n").is_empty());
    }

    #[test]
    fn w1_warns_on_bare_unwrap_in_ft_zones_only() {
        let src = "let v = x.unwrap();\n";
        let diags = run("ft/recovery_ops.rs", src);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].severity, Severity::Warning);
        assert!(run("pregel/engine.rs", src).is_empty());
        assert!(run("storage/hdfs.rs", "let v = x.expect(\"lock poisoned\");\n").is_empty());
        assert_eq!(run("storage/hdfs.rs", "let v = x.expect(\"\");\n").len(), 1);
    }

    #[test]
    fn every_baseline_rule_is_documented() {
        for rule in BASELINE_RULES {
            assert!(rule_doc(rule).is_some(), "{rule} lacks docs");
        }
        assert!(rule_doc("W0").is_some());
    }
}
