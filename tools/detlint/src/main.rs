//! detlint CLI.
//!
//! ```text
//! cargo run -p detlint                      # lint rust/src, check baseline
//! cargo run -p detlint -- --deny-warnings   # what CI and tier-1 run
//! cargo run -p detlint -- --explain D3      # rule documentation
//! cargo run -p detlint -- --write-baseline  # after reviewing new waivers
//! ```
//!
//! Exit codes: 0 clean, 1 violations or baseline mismatch, 2 usage
//! error.

use std::path::PathBuf;
use std::process::ExitCode;

use detlint::diag::Severity;
use detlint::rules::{rule_doc, BASELINE_RULES};
use detlint::waiver::{compare_baseline, format_baseline, parse_baseline};

struct Options {
    root: PathBuf,
    baseline: Option<PathBuf>,
    deny_warnings: bool,
    write_baseline: bool,
}

fn usage() -> &'static str {
    "usage: detlint [--root DIR] [--baseline FILE|none] [--deny-warnings]\n\
     \x20              [--write-baseline] [--explain RULE] [--list-rules]\n\
     \n\
     Lints rust/src against the determinism contracts (DESIGN.md §10).\n\
     \x20 --root DIR        directory to scan (default: rust/src)\n\
     \x20 --baseline FILE   waiver baseline to ratchet against\n\
     \x20                   (default: tools/detlint/baseline.txt; `none` skips)\n\
     \x20 --deny-warnings   treat W1 warnings as errors (CI / tier-1 mode)\n\
     \x20 --write-baseline  rewrite the baseline from the current tree\n\
     \x20 --explain RULE    print the contract behind a rule (D1..D5, W1, W0)\n\
     \x20 --list-rules      list all rules with one-line summaries"
}

/// Default scan root: `rust/src` from the workspace root if we are
/// there, else relative to this crate's manifest (so the tier-1 test
/// binary works from any cwd).
fn default_root() -> PathBuf {
    let cwd = PathBuf::from("rust/src");
    if cwd.is_dir() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../rust/src")
}

fn default_baseline() -> PathBuf {
    let local = PathBuf::from("tools/detlint/baseline.txt");
    if local.is_file() {
        return local;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("baseline.txt")
}

fn parse_args(args: &[String]) -> Result<Option<Options>, String> {
    let mut opts = Options {
        root: default_root(),
        baseline: Some(default_baseline()),
        deny_warnings: false,
        write_baseline: false,
    };
    let mut i = 0usize;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                i += 1;
                let v = args.get(i).ok_or("--root requires a directory")?;
                opts.root = PathBuf::from(v);
            }
            "--baseline" => {
                i += 1;
                let v = args.get(i).ok_or("--baseline requires a path or `none`")?;
                opts.baseline = if v == "none" { None } else { Some(PathBuf::from(v)) };
            }
            "--deny-warnings" => opts.deny_warnings = true,
            "--write-baseline" => opts.write_baseline = true,
            "--explain" => {
                i += 1;
                let rule = args.get(i).ok_or("--explain requires a rule id")?;
                match rule_doc(rule) {
                    Some(doc) => {
                        println!("{rule}: {doc}");
                        return Ok(None);
                    }
                    None => return Err(format!("unknown rule `{rule}`")),
                }
            }
            "--list-rules" => {
                for rule in BASELINE_RULES.iter().chain(["W0"].iter()) {
                    let doc = rule_doc(rule).unwrap_or("");
                    let first = doc.split('.').next().unwrap_or(doc);
                    println!("{rule}  {first}.");
                }
                return Ok(None);
            }
            "--help" | "-h" => {
                println!("{}", usage());
                return Ok(None);
            }
            other => return Err(format!("unknown argument `{other}`")),
        }
        i += 1;
    }
    Ok(Some(opts))
}

fn run(opts: &Options) -> Result<bool, String> {
    if !opts.root.is_dir() {
        return Err(format!("scan root {} is not a directory", opts.root.display()));
    }
    let tree = detlint::lint_tree(&opts.root)
        .map_err(|e| format!("scanning {}: {e}", opts.root.display()))?;

    let mut failed = false;
    let mut errors = 0usize;
    let mut warnings = 0usize;
    for d in &tree.active {
        eprintln!("{}", d.render());
        match d.severity {
            Severity::Error => errors += 1,
            Severity::Warning => warnings += 1,
        }
    }
    if errors > 0 || (opts.deny_warnings && warnings > 0) {
        failed = true;
    }

    let counts = tree.waived_counts();
    if opts.write_baseline {
        let path = opts
            .baseline
            .clone()
            .ok_or("--write-baseline needs a baseline path (not `none`)")?;
        std::fs::write(&path, format_baseline(&counts))
            .map_err(|e| format!("writing {}: {e}", path.display()))?;
        println!("wrote baseline to {}", path.display());
    } else if let Some(path) = &opts.baseline {
        let content = std::fs::read_to_string(path)
            .map_err(|e| format!("reading baseline {}: {e}", path.display()))?;
        let baseline = parse_baseline(&content)?;
        for msg in compare_baseline(&counts, &baseline) {
            eprintln!("baseline mismatch: {msg}");
            failed = true;
        }
    }

    let n_files = tree.files.len();
    let n_waived: usize = counts.values().sum();
    if failed {
        eprintln!(
            "detlint: FAILED — {errors} error(s), {warnings} warning(s) in {n_files} file(s) \
             ({n_waived} waived)"
        );
    } else {
        println!(
            "detlint: OK — {n_files} file(s) clean, {n_waived} waived, {warnings} warning(s)"
        );
    }
    Ok(!failed)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = match parse_args(&args) {
        Ok(Some(opts)) => opts,
        Ok(None) => return ExitCode::SUCCESS,
        Err(msg) => {
            eprintln!("detlint: {msg}\n{}", usage());
            return ExitCode::from(2);
        }
    };
    match run(&opts) {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::from(1),
        Err(msg) => {
            eprintln!("detlint: {msg}");
            ExitCode::from(2)
        }
    }
}
