//! detlint — determinism-contract linter for the lwcp codebase.
//!
//! The engine's fault-tolerance story rests on bit-identical replay
//! (DESIGN.md §5): identical inputs must produce identical vertex
//! state, wire bytes, checkpoint digests and reports, on any thread
//! count, before and after recovery. Most regressions against that
//! contract are *lexically visible* — a `HashMap` iteration, an
//! `Instant::now()`, an open-coded float fold — long before they are
//! observable in a golden test. detlint scans `rust/src` for exactly
//! those shapes and fails the build.
//!
//! Zero dependencies by design: the scrubber ([`scrub`]) blanks
//! comments and literals, the rules ([`rules`]) pattern-match scrubbed
//! lines within path-prefix zones, and waivers ([`waiver`]) are
//! ratcheted against a checked-in baseline. See `DESIGN.md §10` for
//! the rule-to-contract mapping and waiver etiquette.

pub mod diag;
pub mod rules;
pub mod scrub;
pub mod waiver;

use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use diag::{sort_diagnostics, Diagnostic};
use waiver::{apply_waivers, parse_waivers, waived_counts};

/// Lint result for one source file.
pub struct FileLint {
    /// Path relative to the scanned root, `/`-separated.
    pub relpath: String,
    /// Diagnostics still in force (includes `W0` hygiene errors).
    pub active: Vec<Diagnostic>,
    /// Diagnostics suppressed by a valid waiver.
    pub waived: Vec<Diagnostic>,
}

/// Lint result for a whole tree.
pub struct TreeLint {
    pub files: Vec<FileLint>,
    /// All active diagnostics across files, sorted for reporting.
    pub active: Vec<Diagnostic>,
    /// All waived diagnostics across files, sorted.
    pub waived: Vec<Diagnostic>,
}

impl TreeLint {
    /// Waived-violation counts per baseline rule (zero-filled).
    pub fn waived_counts(&self) -> BTreeMap<String, usize> {
        waived_counts(&self.waived)
    }
}

/// Lint one source string as if it lived at `relpath` under the root.
pub fn lint_source(relpath: &str, src: &str) -> FileLint {
    let sc = scrub::scrub(src);
    let raw = rules::check_file(relpath, &sc);
    let (waivers, mut malformed) = parse_waivers(relpath, &sc.raw_lines);
    let (mut active, waived, hygiene) = apply_waivers(relpath, &sc.raw_lines, &waivers, raw);
    active.append(&mut malformed);
    active.extend(hygiene);
    FileLint {
        relpath: relpath.to_string(),
        active,
        waived,
    }
}

/// Recursively collect `.rs` files under `root`, sorted by relative
/// path so diagnostics and waiver counts are stable across platforms.
pub fn collect_rs_files(root: &Path) -> io::Result<Vec<PathBuf>> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
        let mut entries: Vec<PathBuf> =
            fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
        entries.sort();
        for path in entries {
            if path.is_dir() {
                walk(&path, out)?;
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    walk(root, &mut out)?;
    Ok(out)
}

/// Lint every `.rs` file under `root`.
pub fn lint_tree(root: &Path) -> io::Result<TreeLint> {
    let mut files = Vec::new();
    let mut active = Vec::new();
    let mut waived = Vec::new();
    for path in collect_rs_files(root)? {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = fs::read_to_string(&path)?;
        let lint = lint_source(&rel, &src);
        active.extend(lint.active.iter().cloned());
        waived.extend(lint.waived.iter().cloned());
        files.push(lint);
    }
    sort_diagnostics(&mut active);
    sort_diagnostics(&mut waived);
    Ok(TreeLint {
        files,
        active,
        waived,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lint_source_merges_rule_and_hygiene_diagnostics() {
        let src = "// detlint: allow(D1): unused on purpose\nlet t = std::time::Instant::now();\n";
        let lint = lint_source("ft/mod.rs", src);
        // One D2 (not suppressed: waiver names D1) + one W0 (stale).
        assert_eq!(lint.active.len(), 2);
        assert!(lint.active.iter().any(|d| d.rule == "D2"));
        assert!(lint.active.iter().any(|d| d.rule == "W0"));
        assert!(lint.waived.is_empty());
    }

    #[test]
    fn lint_source_clean_file_is_clean() {
        let src = "use std::collections::BTreeMap;\n\npub fn f() -> u32 {\n    1\n}\n";
        let lint = lint_source("pregel/engine.rs", src);
        assert!(lint.active.is_empty());
        assert!(lint.waived.is_empty());
    }

    #[test]
    fn waived_counts_are_zero_filled() {
        let lint = lint_source("sim/cost.rs", "pub fn f() {}\n");
        let tree = TreeLint {
            files: vec![],
            active: lint.active,
            waived: lint.waived,
        };
        let counts = tree.waived_counts();
        assert_eq!(counts.len(), rules::BASELINE_RULES.len());
        assert!(counts.values().all(|&c| c == 0));
    }
}
