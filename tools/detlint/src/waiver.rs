//! Inline waivers and the checked-in waiver baseline.
//!
//! A waiver is a comment of the form
//!
//! ```text
//! // detlint: allow(D1): membership-only set, order never iterated
//! ```
//!
//! placed either on the offending line (trailing comment) or on the
//! line directly above it. A waiver must name a known rule, carry a
//! non-empty justification, and actually suppress at least one
//! diagnostic — anything else is a `W0` (waiver hygiene) error, so
//! stale waivers cannot linger.
//!
//! Waived counts per rule are compared against `baseline.txt`
//! (checked in next to the crate). The comparison is a two-sided
//! ratchet: a *new* un-baselined waiver fails the lint, and a *stale*
//! baseline entry (more waivers recorded than exist) also fails, so
//! the baseline only moves with an intentional edit.

use std::collections::BTreeMap;

use crate::diag::{Diagnostic, Severity};
use crate::rules::{rule_doc, BASELINE_RULES};

const MARKER: &str = "detlint: allow(";

/// One parsed waiver comment.
#[derive(Debug, Clone)]
pub struct Waiver {
    /// Rule the waiver names, e.g. `D1`.
    pub rule: String,
    /// 1-based line the comment sits on.
    pub line: usize,
    /// Justification text after the second colon (trimmed).
    pub justification: String,
}

/// Scan raw source lines for waiver comments. Malformed waivers
/// (unknown rule, missing/empty justification) are returned as `W0`
/// diagnostics instead.
pub fn parse_waivers(relpath: &str, raw_lines: &[String]) -> (Vec<Waiver>, Vec<Diagnostic>) {
    let mut waivers = Vec::new();
    let mut bad = Vec::new();
    for (idx, raw) in raw_lines.iter().enumerate() {
        let Some(pos) = raw.find(MARKER) else {
            continue;
        };
        let lineno = idx + 1;
        let rest = &raw[pos + MARKER.len()..];
        let w0 = |msg: String| Diagnostic {
            rule: "W0",
            severity: Severity::Error,
            file: relpath.to_string(),
            line: lineno,
            message: msg,
            excerpt: raw.trim().to_string(),
        };
        let Some(close) = rest.find(')') else {
            bad.push(w0("malformed waiver: missing `)` after rule id".to_string()));
            continue;
        };
        let rule = rest[..close].trim().to_string();
        if rule_doc(&rule).is_none() || rule == "W0" {
            bad.push(w0(format!("waiver names unknown or unwaivable rule `{rule}`")));
            continue;
        }
        let after = &rest[close + 1..];
        let justification = after.strip_prefix(':').map(str::trim).unwrap_or("");
        if justification.is_empty() {
            bad.push(w0(format!(
                "waiver for {rule} lacks a justification: write \
                 `// detlint: allow({rule}): <why this is safe>`"
            )));
            continue;
        }
        waivers.push(Waiver {
            rule,
            line: lineno,
            justification: justification.to_string(),
        });
    }
    (waivers, bad)
}

/// Apply `waivers` to `diags`. A waiver suppresses diagnostics of its
/// rule on its own line or the next line. Returns
/// `(active, waived, hygiene)` where `hygiene` holds `W0` errors for
/// waivers that suppressed nothing.
pub fn apply_waivers(
    relpath: &str,
    raw_lines: &[String],
    waivers: &[Waiver],
    diags: Vec<Diagnostic>,
) -> (Vec<Diagnostic>, Vec<Diagnostic>, Vec<Diagnostic>) {
    let mut used = vec![false; waivers.len()];
    let mut active = Vec::new();
    let mut waived = Vec::new();
    for d in diags {
        let hit = waivers.iter().enumerate().find(|(_, w)| {
            w.rule == d.rule && (w.line == d.line || w.line + 1 == d.line)
        });
        if let Some((wi, _)) = hit {
            used[wi] = true;
            waived.push(d);
        } else {
            active.push(d);
        }
    }
    let mut hygiene = Vec::new();
    for (wi, w) in waivers.iter().enumerate() {
        if !used[wi] {
            hygiene.push(Diagnostic {
                rule: "W0",
                severity: Severity::Error,
                file: relpath.to_string(),
                line: w.line,
                message: format!(
                    "stale waiver: no {} violation on this or the next line — \
                     remove it (and update baseline.txt)",
                    w.rule
                ),
                excerpt: raw_lines
                    .get(w.line - 1)
                    .map(|l| l.trim().to_string())
                    .unwrap_or_default(),
            });
        }
    }
    (active, waived, hygiene)
}

/// Count waived diagnostics per baseline rule, zero-filled so the
/// output always lists every rule.
pub fn waived_counts(waived: &[Diagnostic]) -> BTreeMap<String, usize> {
    let mut counts: BTreeMap<String, usize> = BASELINE_RULES
        .iter()
        .map(|r| (r.to_string(), 0))
        .collect();
    for d in waived {
        *counts.entry(d.rule.to_string()).or_insert(0) += 1;
    }
    counts
}

/// Render counts in `baseline.txt` format: `RULE count` per line,
/// `#` comments allowed.
pub fn format_baseline(counts: &BTreeMap<String, usize>) -> String {
    let mut out = String::from(
        "# detlint waiver baseline: waived violations per rule.\n\
         # Regenerate with `cargo run -p detlint -- --write-baseline`\n\
         # after reviewing any new `// detlint: allow(...)` comment.\n",
    );
    for (rule, count) in counts {
        out.push_str(&format!("{rule} {count}\n"));
    }
    out
}

/// Parse `baseline.txt` content. Unknown rules or garbage lines are
/// reported as error strings.
pub fn parse_baseline(content: &str) -> Result<BTreeMap<String, usize>, String> {
    let mut counts: BTreeMap<String, usize> = BASELINE_RULES
        .iter()
        .map(|r| (r.to_string(), 0))
        .collect();
    for (idx, line) in content.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let rule = parts.next().unwrap_or("");
        let count = parts
            .next()
            .and_then(|c| c.parse::<usize>().ok())
            .ok_or_else(|| format!("baseline line {}: expected `RULE count`, got `{line}`", idx + 1))?;
        if !BASELINE_RULES.contains(&rule) {
            return Err(format!("baseline line {}: unknown rule `{rule}`", idx + 1));
        }
        counts.insert(rule.to_string(), count);
    }
    Ok(counts)
}

/// Two-sided ratchet comparison. Returns human-readable mismatch
/// messages; empty means the baseline matches exactly.
pub fn compare_baseline(
    actual: &BTreeMap<String, usize>,
    baseline: &BTreeMap<String, usize>,
) -> Vec<String> {
    let mut msgs = Vec::new();
    for rule in BASELINE_RULES {
        let a = actual.get(rule).copied().unwrap_or(0);
        let b = baseline.get(rule).copied().unwrap_or(0);
        if a > b {
            msgs.push(format!(
                "{rule}: {a} waiver(s) in tree but baseline records {b} — new \
                 waivers need review; rerun with --write-baseline after review"
            ));
        } else if a < b {
            msgs.push(format!(
                "{rule}: baseline records {b} waiver(s) but only {a} in tree — \
                 stale baseline; rerun with --write-baseline to ratchet down"
            ));
        }
    }
    msgs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scrub::scrub;

    fn lines(src: &str) -> Vec<String> {
        src.lines().map(String::from).collect()
    }

    #[test]
    fn parse_accepts_well_formed_waiver() {
        let raw = lines("// detlint: allow(D1): membership-only, never iterated\nuse x;\n");
        let (ws, bad) = parse_waivers("a.rs", &raw);
        assert!(bad.is_empty());
        assert_eq!(ws.len(), 1);
        assert_eq!(ws[0].rule, "D1");
        assert_eq!(ws[0].line, 1);
        assert!(ws[0].justification.contains("membership"));
    }

    #[test]
    fn parse_rejects_unknown_rule_and_empty_justification() {
        let raw = lines("// detlint: allow(D9): whatever\n// detlint: allow(D2):\n// detlint: allow(D2)\n");
        let (ws, bad) = parse_waivers("a.rs", &raw);
        assert!(ws.is_empty());
        assert_eq!(bad.len(), 3);
        assert!(bad.iter().all(|d| d.rule == "W0"));
    }

    #[test]
    fn waiver_suppresses_same_line_and_next_line() {
        let src = "// detlint: allow(D2): fixture\nlet t = std::time::Instant::now();\n";
        let sc = scrub(src);
        let diags = crate::rules::check_file("ft/mod.rs", &sc);
        assert_eq!(diags.len(), 1);
        let (ws, bad) = parse_waivers("ft/mod.rs", &sc.raw_lines);
        assert!(bad.is_empty());
        let (active, waived, hygiene) = apply_waivers("ft/mod.rs", &sc.raw_lines, &ws, diags);
        assert!(active.is_empty());
        assert_eq!(waived.len(), 1);
        assert!(hygiene.is_empty());
    }

    #[test]
    fn unused_waiver_is_a_hygiene_error() {
        let src = "// detlint: allow(D1): nothing here\nlet x = 1;\n";
        let sc = scrub(src);
        let (ws, _) = parse_waivers("a.rs", &sc.raw_lines);
        let (_, _, hygiene) = apply_waivers("a.rs", &sc.raw_lines, &ws, Vec::new());
        assert_eq!(hygiene.len(), 1);
        assert_eq!(hygiene[0].rule, "W0");
        assert!(hygiene[0].message.contains("stale"));
    }

    #[test]
    fn waiver_for_wrong_rule_does_not_suppress() {
        let src = "// detlint: allow(D1): wrong rule\nlet t = std::time::Instant::now();\n";
        let sc = scrub(src);
        let diags = crate::rules::check_file("ft/mod.rs", &sc);
        let (ws, _) = parse_waivers("ft/mod.rs", &sc.raw_lines);
        let (active, waived, hygiene) = apply_waivers("ft/mod.rs", &sc.raw_lines, &ws, diags);
        assert_eq!(active.len(), 1);
        assert!(waived.is_empty());
        assert_eq!(hygiene.len(), 1);
    }

    #[test]
    fn baseline_round_trip_and_ratchet() {
        let counts = waived_counts(&[]);
        let text = format_baseline(&counts);
        let parsed = parse_baseline(&text).expect("round trip");
        assert_eq!(parsed, counts);
        assert!(compare_baseline(&counts, &parsed).is_empty());

        let mut grown = counts.clone();
        grown.insert("D1".to_string(), 1);
        let up = compare_baseline(&grown, &counts);
        assert_eq!(up.len(), 1);
        assert!(up[0].contains("new"));
        let down = compare_baseline(&counts, &grown);
        assert_eq!(down.len(), 1);
        assert!(down[0].contains("stale"));
    }

    #[test]
    fn baseline_rejects_garbage() {
        assert!(parse_baseline("D1 not-a-number\n").is_err());
        assert!(parse_baseline("D9 3\n").is_err());
        assert!(parse_baseline("# comment only\n\n").is_ok());
    }
}
