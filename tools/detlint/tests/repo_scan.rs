//! Tier-1 contract enforcement: `cargo test -q` at the workspace root
//! runs this, so a determinism-contract violation anywhere in
//! `rust/src` fails the build — not just CI's dedicated detlint step.

use std::collections::BTreeMap;
use std::path::PathBuf;

use detlint::diag::Severity;
use detlint::waiver::{compare_baseline, parse_baseline};

fn repo_src() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../rust/src")
}

fn baseline_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("baseline.txt")
}

#[test]
fn rust_src_has_no_active_violations() {
    let root = repo_src();
    if !root.is_dir() {
        eprintln!(
            "rust/src NOT FOUND at {} — skipping the repo-wide contract scan. \
             detlint is enforcing NOTHING; fix the layout or the path above.",
            root.display()
        );
        return;
    }
    let tree = detlint::lint_tree(&root).expect("scanning rust/src");
    let mut errors = 0usize;
    let mut warnings = 0usize;
    for d in &tree.active {
        eprintln!("{}", d.render());
        match d.severity {
            Severity::Error => errors += 1,
            Severity::Warning => warnings += 1,
        }
    }
    assert_eq!(errors, 0, "determinism-contract errors in rust/src (see stderr)");
    // Tier-1 runs warn-tier rules at full strength (`--deny-warnings`
    // semantics): a bare unwrap on the recovery path fails the build.
    assert_eq!(warnings, 0, "W1 warnings in rust/src (see stderr)");
}

#[test]
fn waiver_counts_match_checked_in_baseline() {
    let root = repo_src();
    let bpath = baseline_path();
    if !root.is_dir() || !bpath.is_file() {
        eprintln!(
            "detlint baseline check SKIPPED: missing {} or {} — the waiver \
             ratchet is NOT being enforced.",
            root.display(),
            bpath.display()
        );
        return;
    }
    let tree = detlint::lint_tree(&root).expect("scanning rust/src");
    let content = std::fs::read_to_string(&bpath).expect("reading baseline.txt");
    let baseline = parse_baseline(&content).expect("parsing baseline.txt");
    let mismatches = compare_baseline(&tree.waived_counts(), &baseline);
    assert!(
        mismatches.is_empty(),
        "waiver baseline drift:\n  {}",
        mismatches.join("\n  ")
    );
}

#[test]
fn baseline_ratchet_fails_on_drift_in_both_directions() {
    let content = std::fs::read_to_string(baseline_path()).expect("reading baseline.txt");
    let baseline = parse_baseline(&content).expect("parsing baseline.txt");

    // A new un-baselined waiver must fail...
    let mut grown: BTreeMap<String, usize> = baseline.clone();
    *grown.get_mut("D1").expect("baseline lists D1") += 1;
    let up = compare_baseline(&grown, &baseline);
    assert_eq!(up.len(), 1, "un-baselined waiver not caught");
    assert!(up[0].contains("new waivers"), "wrong message: {}", up[0]);

    // ...and a stale (over-recorded) baseline must also fail.
    let down = compare_baseline(&baseline, &grown);
    assert_eq!(down.len(), 1, "stale baseline not caught");
    assert!(down[0].contains("stale baseline"), "wrong message: {}", down[0]);
}
