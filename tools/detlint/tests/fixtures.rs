//! Fixture-corpus test: every rule must have a positive (caught), a
//! negative (not caught) and a waived case, and each fixture's
//! diagnostics must match its `//@` directives exactly.
//!
//! Fixture format (see `fixtures/*.rs`):
//!
//! ```text
//! //@ zone: pregel/engine.rs        <- pretend path inside rust/src
//! //@ active: D1@4, D1@7            <- expected active (rule@line)
//! //@ waived: D1@9                  <- expected waived (optional)
//! ```

use std::collections::BTreeSet;
use std::path::PathBuf;

fn fixtures_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures")
}

fn directive(src: &str, key: &str) -> Option<String> {
    let tag = format!("//@ {key}:");
    src.lines()
        .find_map(|l| l.strip_prefix(&tag))
        .map(|rest| rest.trim().to_string())
}

/// Parse "D1@4, D1@7" into a sorted multiset of (rule, line).
fn parse_expectations(list: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for item in list.split(',') {
        let item = item.trim();
        if item.is_empty() {
            continue;
        }
        let (rule, line) = item
            .split_once('@')
            .unwrap_or_else(|| panic!("bad expectation `{item}` (want RULE@LINE)"));
        let line: usize = line
            .trim()
            .parse()
            .unwrap_or_else(|_| panic!("bad line in expectation `{item}`"));
        out.push((rule.trim().to_string(), line));
    }
    out.sort();
    out
}

fn found(diags: &[detlint::diag::Diagnostic]) -> Vec<(String, usize)> {
    let mut out: Vec<(String, usize)> =
        diags.iter().map(|d| (d.rule.to_string(), d.line)).collect();
    out.sort();
    out
}

#[test]
fn fixtures_match_their_directives() {
    let dir = fixtures_dir();
    if !dir.is_dir() {
        eprintln!(
            "detlint fixture corpus MISSING at {} — skipping the fixture test. \
             The determinism-contract rules are NOT being exercised; restore \
             tools/detlint/fixtures/ to re-enable.",
            dir.display()
        );
        return;
    }
    let mut active_rules: BTreeSet<String> = BTreeSet::new();
    let mut waived_rules: BTreeSet<String> = BTreeSet::new();
    let mut checked = 0usize;
    let files = detlint::collect_rs_files(&dir).expect("reading fixtures dir");
    assert!(!files.is_empty(), "fixture dir {} has no .rs files", dir.display());
    for path in files {
        let src = std::fs::read_to_string(&path).expect("reading fixture");
        let name = path.file_name().unwrap_or_default().to_string_lossy().to_string();
        let zone = directive(&src, "zone")
            .unwrap_or_else(|| panic!("{name}: missing `//@ zone:` directive"));
        let expect_active = parse_expectations(&directive(&src, "active").unwrap_or_default());
        let expect_waived = parse_expectations(&directive(&src, "waived").unwrap_or_default());

        let lint = detlint::lint_source(&zone, &src);
        assert_eq!(
            found(&lint.active),
            expect_active,
            "{name}: active diagnostics diverge from //@ active directive"
        );
        assert_eq!(
            found(&lint.waived),
            expect_waived,
            "{name}: waived diagnostics diverge from //@ waived directive"
        );
        active_rules.extend(expect_active.into_iter().map(|(r, _)| r));
        waived_rules.extend(expect_waived.into_iter().map(|(r, _)| r));
        checked += 1;
    }
    assert!(checked >= 20, "fixture corpus shrank to {checked} files");
    // Every rule must be demonstrably caught and demonstrably waivable.
    for rule in ["D1", "D2", "D3", "D4", "D5", "W1", "W0"] {
        assert!(active_rules.contains(rule), "no positive fixture catches {rule}");
    }
    for rule in ["D1", "D2", "D3", "D4", "D5", "W1"] {
        assert!(waived_rules.contains(rule), "no fixture waives {rule}");
    }
}
