"""Build-time compile package for the lwcp engine (never imported at runtime).

Layout:
  kernels/   Layer-1 Pallas kernels + pure-jnp oracles (ref.py)
  model.py   Layer-2 JAX per-partition compute graphs (call kernels.*)
  aot.py     jax.jit(...).lower() -> HLO text -> artifacts/*.hlo.txt
"""
