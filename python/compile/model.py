"""Layer-2 JAX compute graphs for the lwcp engine.

Each function here is the *whole* per-superstep numeric update for one
worker partition, padded to a size bucket. It calls the Layer-1 Pallas
kernels and adds the partition-level reductions (the per-worker partial
aggregator values), so that a single AOT-compiled executable per
(function, bucket) covers the full hot-path numeric work of a superstep.

Lowered once by :mod:`compile.aot`; executed from Rust via PJRT.
"""

import jax.numpy as jnp

from compile.kernels.pagerank import pagerank_update
from compile.kernels.minstep import min_update

#: Size buckets for worker partitions. A worker pads |V_W| up to the
#: smallest bucket; the Rust runtime compiles one executable per bucket
#: lazily. All buckets are multiples of the kernels' BLOCK (512).
BUCKETS = (512, 1024, 4096, 16384, 65536, 262144)

DAMPING = 0.85


def pagerank_step(old_rank, msg_sum, deg):
    """PageRank superstep update for one padded partition.

    Returns ``(new_rank[N], contrib[N], delta_sum[] )`` where delta_sum is
    the partition's partial L1-delta aggregator (summed across workers by
    the Rust coordinator to drive the convergence check).
    """
    new, contrib, delta = pagerank_update(old_rank, msg_sum, deg, damping=DAMPING)
    return new, contrib, jnp.sum(delta)


def min_step(cur, incoming):
    """Min-fold superstep update (Hash-Min CC / SSSP) for one partition.

    Returns ``(new[N], changed[N], changed_count[])``; changed_count is the
    partition's partial "number of updated vertices" aggregator (the job
    halts when the global count is 0).
    """
    new, changed = min_update(cur, incoming)
    return new, changed, jnp.sum(changed)
