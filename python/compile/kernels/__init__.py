"""Layer-1 Pallas kernels for the lwcp engine's per-partition vertex update.

Each kernel has a pure-jnp oracle in :mod:`compile.kernels.ref`; pytest
(``python/tests/``) asserts allclose between the two across shapes and
value distributions (hypothesis sweeps).

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin used by
the Rust runtime cannot execute Mosaic custom-calls, and interpret mode
lowers to plain HLO ops that run on any backend. The BlockSpec structure
(tiling for VMEM) is still authored the way a real TPU kernel would be —
see DESIGN.md §8 for the footprint/roofline estimate.
"""

from compile.kernels.pagerank import pagerank_update
from compile.kernels.minstep import min_update

__all__ = ["pagerank_update", "min_update"]
