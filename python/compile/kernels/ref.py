"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

These are deliberately written as straight-line jnp with no tiling or
pallas machinery, so a bug in the kernels' BlockSpec plumbing cannot hide
in the oracle too.
"""

import jax.numpy as jnp


def pagerank_update_ref(old_rank, msg_sum, deg, *, damping=0.85):
    """Oracle for :func:`compile.kernels.pagerank.pagerank_update`."""
    new = (1.0 - damping) + damping * msg_sum
    contrib = jnp.where(deg > 0, new / jnp.where(deg > 0, deg, 1.0), 0.0)
    delta = jnp.abs(new - old_rank)
    return new, contrib, delta


def min_update_ref(cur, incoming):
    """Oracle for :func:`compile.kernels.minstep.min_update`."""
    new = jnp.minimum(cur, incoming)
    changed = jnp.where(new < cur, 1.0, 0.0)
    return new, changed


def pagerank_step_ref(old_rank, msg_sum, deg, *, damping=0.85):
    """Oracle for the Layer-2 model fn (kernel outputs + delta reduction)."""
    new, contrib, delta = pagerank_update_ref(old_rank, msg_sum, deg, damping=damping)
    return new, contrib, jnp.sum(delta)


def min_step_ref(cur, incoming):
    """Oracle for the Layer-2 min step (kernel outputs + changed count)."""
    new, changed = min_update_ref(cur, incoming)
    return new, changed, jnp.sum(changed)
