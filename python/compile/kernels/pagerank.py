"""Pallas kernel: Pregel-style PageRank vertex update.

For every vertex slot ``i`` of a worker partition (padded to a size
bucket):

    new_rank[i] = (1 - d) + d * msg_sum[i]          (Pregel's unnormalized
                                                     damped update)
    contrib[i]  = new_rank[i] / deg[i]  if deg[i] > 0 else 0
                                                    (the per-out-edge
                                                     message value)
    delta[i]    = |new_rank[i] - old_rank[i]|       (for the convergence
                                                     aggregator)

Padded slots are handled by the caller passing ``deg = 0`` and
``msg_sum = 0`` for them; their contrib is 0 and their delta is 0 as long
as old_rank is also the padding value (the Rust runtime pads with the
damping floor ``1 - d`` so delta stays exactly 0 — see
rust/src/runtime/registry.rs).

VMEM tiling: 1-D grid over blocks of ``BLOCK`` vertices; three f32 input
vectors + three f32 output vectors per block = 6 * BLOCK * 4 bytes
(12 KiB at BLOCK=512), far under the ~16 MiB VMEM budget; the kernel is
element-wise (VPU work, no MXU), so on real hardware it is HBM-bandwidth
bound.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512


def _pagerank_kernel(old_ref, msg_ref, deg_ref, new_ref, contrib_ref, delta_ref, *, damping):
    old = old_ref[...]
    msg = msg_ref[...]
    deg = deg_ref[...]
    new = (1.0 - damping) + damping * msg
    new_ref[...] = new
    # Guard the divide: padded / sink slots have deg == 0.
    safe_deg = jnp.where(deg > 0, deg, 1.0)
    contrib_ref[...] = jnp.where(deg > 0, new / safe_deg, 0.0)
    delta_ref[...] = jnp.abs(new - old)


@functools.partial(jax.jit, static_argnames=("damping", "block"))
def pagerank_update(old_rank, msg_sum, deg, *, damping=0.85, block=BLOCK):
    """Run the PageRank update kernel over a padded partition.

    Args:
      old_rank: f32[N] previous rank per vertex slot.
      msg_sum: f32[N] combined incoming message sum per vertex slot.
      deg: f32[N] out-degree per slot (0 for sinks and padding).
      damping: damping factor d.
      block: VMEM tile size; N must be a multiple of it.

    Returns:
      (new_rank f32[N], contrib f32[N], delta f32[N]).
    """
    n = old_rank.shape[0]
    assert n % block == 0, f"partition size {n} not a multiple of block {block}"
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shape = [jax.ShapeDtypeStruct((n,), jnp.float32)] * 3
    return tuple(
        pl.pallas_call(
            functools.partial(_pagerank_kernel, damping=damping),
            grid=grid,
            in_specs=[spec, spec, spec],
            out_specs=[spec, spec, spec],
            out_shape=out_shape,
            interpret=True,
        )(old_rank, msg_sum, deg)
    )
