"""Pallas kernel: min-fold vertex update (Hash-Min CC / SSSP relaxation).

For every vertex slot ``i``:

    new[i]     = min(cur[i], incoming[i])
    changed[i] = 1.0 if new[i] < cur[i] else 0.0

``incoming`` is the combiner-reduced minimum of the messages received by
the vertex this superstep, with +inf for vertices that received nothing
(and for padded slots, whose cur is also +inf so they never report a
change).

The ``changed`` flag is exactly the traversal-style "value was updated"
bit that the paper's LWCP requires to be part of the vertex state
(Section 4, *traversal style* algorithms): message generation after a
checkpoint reload emits messages only for vertices whose stored flag is
set.

Same tiling story as the PageRank kernel: element-wise over BLOCK-sized
VMEM tiles, bandwidth-bound.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512


def _min_kernel(cur_ref, inc_ref, new_ref, changed_ref):
    cur = cur_ref[...]
    inc = inc_ref[...]
    new = jnp.minimum(cur, inc)
    new_ref[...] = new
    changed_ref[...] = jnp.where(new < cur, 1.0, 0.0)


@functools.partial(jax.jit, static_argnames=("block",))
def min_update(cur, incoming, *, block=BLOCK):
    """Run the min-fold kernel over a padded partition.

    Args:
      cur: f32[N] current value per slot (component id, or sssp distance).
      incoming: f32[N] min of incoming messages, +inf where none.
      block: VMEM tile size; N must be a multiple of it.

    Returns:
      (new f32[N], changed f32[N] of {0.0, 1.0}).
    """
    n = cur.shape[0]
    assert n % block == 0, f"partition size {n} not a multiple of block {block}"
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shape = [
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    ]
    return tuple(
        pl.pallas_call(
            _min_kernel,
            grid=grid,
            in_specs=[spec, spec],
            out_specs=[spec, spec],
            out_shape=out_shape,
            interpret=True,
        )(cur, incoming)
    )
