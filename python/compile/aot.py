"""AOT lowering: Layer-2 JAX functions -> HLO *text* -> artifacts/.

HLO text (not ``XlaComputation.serialize()``) is the interchange format:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the
Rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly.

Outputs (for every bucket N in model.BUCKETS):
  artifacts/pagerank_step_<N>.hlo.txt   (f32[N], f32[N], f32[N]) ->
                                        tuple(f32[N], f32[N], f32[])
  artifacts/min_step_<N>.hlo.txt        (f32[N], f32[N]) ->
                                        tuple(f32[N], f32[N], f32[])
  artifacts/manifest.txt                one line per artifact:
                                        <fn> <bucket> <n_inputs> <file>

Usage: ``python -m compile.aot --out-dir ../artifacts`` (see Makefile).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, arg_shapes):
    """jit + lower a function for the given argument shapes."""
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in arg_shapes]
    return jax.jit(fn).lower(*specs)


#: (name, fn, n_inputs). Input shapes are all f32[bucket].
FUNCTIONS = (
    ("pagerank_step", model.pagerank_step, 3),
    ("min_step", model.min_step, 2),
)


def build(out_dir: str, buckets=model.BUCKETS) -> list:
    """Lower every (function, bucket) pair and write artifacts + manifest."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for name, fn, n_inputs in FUNCTIONS:
        for n in buckets:
            lowered = lower_fn(fn, [(n,)] * n_inputs)
            text = to_hlo_text(lowered)
            fname = f"{name}_{n}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest.append((name, n, n_inputs, fname))
            print(f"  lowered {name} bucket={n}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        for name, n, n_inputs, fname in manifest:
            f.write(f"{name} {n} {n_inputs} {fname}\n")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    p.add_argument(
        "--buckets",
        default=None,
        help="comma-separated bucket sizes (default: model.BUCKETS)",
    )
    args = p.parse_args()
    buckets = (
        tuple(int(b) for b in args.buckets.split(",")) if args.buckets else model.BUCKETS
    )
    manifest = build(args.out_dir, buckets)
    print(f"wrote {len(manifest)} artifacts + manifest.txt to {args.out_dir}")


if __name__ == "__main__":
    main()
