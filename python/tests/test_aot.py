"""AOT path smoke tests: lowering produces parseable HLO text whose
numerics (evaluated back through jax) match the oracle, and the manifest
format is what the Rust runtime registry expects."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref


def test_hlo_text_structure(tmp_path):
    manifest = aot.build(str(tmp_path), buckets=(512,))
    assert len(manifest) == len(aot.FUNCTIONS)
    for name, n, n_inputs, fname in manifest:
        text = (tmp_path / fname).read_text()
        assert "ENTRY" in text, f"{fname} missing ENTRY computation"
        assert "f32[512]" in text, f"{fname} missing bucketed shape"
    lines = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert len(lines) == len(manifest)
    for line in lines:
        parts = line.split()
        assert len(parts) == 4
        int(parts[1]), int(parts[2])  # bucket, arity parse as ints


def test_lowered_pagerank_numerics_match_ref():
    n = 512
    lowered = aot.lower_fn(model.pagerank_step, [(n,)] * 3)
    compiled = lowered.compile()
    rng = np.random.default_rng(0)
    old = jnp.asarray(rng.uniform(0, 5, n).astype(np.float32))
    msg = jnp.asarray(rng.uniform(0, 5, n).astype(np.float32))
    deg = jnp.asarray(rng.integers(0, 9, n).astype(np.float32))
    new, contrib, dsum = compiled(old, msg, deg)
    wnew, wcontrib, wdsum = ref.pagerank_step_ref(old, msg, deg)
    np.testing.assert_allclose(new, wnew, rtol=1e-6)
    np.testing.assert_allclose(contrib, wcontrib, rtol=1e-6)
    np.testing.assert_allclose(float(dsum), float(wdsum), rtol=1e-4)


def test_lowered_min_numerics_match_ref():
    n = 512
    lowered = aot.lower_fn(model.min_step, [(n,)] * 2)
    compiled = lowered.compile()
    rng = np.random.default_rng(1)
    cur = jnp.asarray(rng.uniform(0, 100, n).astype(np.float32))
    inc = np.asarray(rng.uniform(0, 100, n).astype(np.float32))
    inc[::3] = np.inf
    inc = jnp.asarray(inc)
    new, changed, count = compiled(cur, inc)
    wnew, wchanged, wcount = ref.min_step_ref(cur, inc)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(wnew))
    assert float(count) == float(wcount)


def test_hlo_text_is_not_serialized_proto(tmp_path):
    # Guard against regressing to .serialize(): the artifact must be text.
    aot.build(str(tmp_path), buckets=(512,))
    for f in os.listdir(tmp_path):
        if f.endswith(".hlo.txt"):
            head = open(os.path.join(tmp_path, f), "rb").read(64)
            head.decode("utf-8")  # raises on binary proto
