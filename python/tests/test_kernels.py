"""Kernel-vs-oracle correctness: the CORE numeric signal of the stack.

Every Pallas kernel must match its pure-jnp oracle bit-for-close across
shapes (all buckets), value distributions, and edge cases (zero degrees,
infinities, already-converged states). Hypothesis drives the sweeps.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pagerank_update, min_update
from compile.kernels import ref
from compile import model

SIZES = [512, 1024, 2048, 4096]


def rand(rng, n, lo=0.0, hi=10.0):
    return jnp.asarray(rng.uniform(lo, hi, size=n).astype(np.float32))


# ---------------------------------------------------------------- pagerank


@pytest.mark.parametrize("n", SIZES)
def test_pagerank_matches_ref(n):
    rng = np.random.default_rng(n)
    old, msg = rand(rng, n), rand(rng, n)
    deg = jnp.asarray(rng.integers(0, 50, size=n).astype(np.float32))
    got = pagerank_update(old, msg, deg)
    want = ref.pagerank_update_ref(old, msg, deg)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-6)


def test_pagerank_zero_degree_contrib_is_zero():
    n = 512
    old = jnp.ones(n)
    msg = jnp.ones(n)
    deg = jnp.zeros(n)
    _, contrib, _ = pagerank_update(old, msg, deg)
    np.testing.assert_array_equal(np.asarray(contrib), np.zeros(n))


def test_pagerank_padding_slots_have_zero_delta():
    # Rust pads with old_rank = 1-d and msg_sum = 0 => new == old => delta 0.
    n = 512
    old = jnp.full(n, 0.15)
    msg = jnp.zeros(n)
    deg = jnp.zeros(n)
    new, contrib, delta = pagerank_update(old, msg, deg)
    np.testing.assert_allclose(np.asarray(new), np.full(n, 0.15), rtol=1e-7)
    np.testing.assert_array_equal(np.asarray(delta), np.zeros(n))
    np.testing.assert_array_equal(np.asarray(contrib), np.zeros(n))


def test_pagerank_damping_fixpoint():
    # msg_sum == rank at the uniform fixpoint: rank 1.0, deg uniform.
    n = 512
    old = jnp.ones(n)
    msg = jnp.ones(n)
    deg = jnp.full(n, 4.0)
    new, contrib, delta = pagerank_update(old, msg, deg)
    np.testing.assert_allclose(np.asarray(new), np.ones(n), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(contrib), np.full(n, 0.25), rtol=1e-6)
    assert float(jnp.max(delta)) < 1e-6


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    damping=st.sampled_from([0.5, 0.85, 0.99]),
)
def test_pagerank_hypothesis_sweep(n_blocks, seed, damping):
    n = 512 * n_blocks
    rng = np.random.default_rng(seed)
    old = rand(rng, n, 0.0, 100.0)
    msg = rand(rng, n, 0.0, 100.0)
    deg = jnp.asarray(rng.integers(0, 1000, size=n).astype(np.float32))
    got = pagerank_update(old, msg, deg, damping=damping)
    want = ref.pagerank_update_ref(old, msg, deg, damping=damping)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------- minstep


@pytest.mark.parametrize("n", SIZES)
def test_min_matches_ref(n):
    rng = np.random.default_rng(n + 7)
    cur = rand(rng, n, 0.0, 1e6)
    inc = rand(rng, n, 0.0, 1e6)
    got = min_update(cur, inc)
    want = ref.min_update_ref(cur, inc)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_min_no_message_is_inf_and_unchanged():
    n = 512
    cur = jnp.arange(n, dtype=jnp.float32)
    inc = jnp.full(n, jnp.inf)
    new, changed = min_update(cur, inc)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(cur))
    np.testing.assert_array_equal(np.asarray(changed), np.zeros(n))


def test_min_strict_improvement_only():
    n = 512
    cur = jnp.full(n, 5.0)
    inc = jnp.full(n, 5.0)  # equal is NOT a change (paper: traversal style)
    new, changed = min_update(cur, inc)
    np.testing.assert_array_equal(np.asarray(changed), np.zeros(n))
    inc2 = jnp.full(n, 4.0)
    _, changed2 = min_update(cur, inc2)
    np.testing.assert_array_equal(np.asarray(changed2), np.ones(n))


def test_min_padding_slots_inert():
    # Padding: cur = +inf, incoming = +inf -> new inf, changed 0.
    n = 512
    cur = jnp.full(n, jnp.inf)
    inc = jnp.full(n, jnp.inf)
    new, changed = min_update(cur, inc)
    assert bool(jnp.all(jnp.isinf(new)))
    np.testing.assert_array_equal(np.asarray(changed), np.zeros(n))


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    inf_frac=st.floats(min_value=0.0, max_value=1.0),
)
def test_min_hypothesis_sweep(n_blocks, seed, inf_frac):
    n = 512 * n_blocks
    rng = np.random.default_rng(seed)
    cur = rand(rng, n, 0.0, 1e9)
    inc = np.asarray(rand(rng, n, 0.0, 1e9)).copy()
    inc[rng.uniform(size=n) < inf_frac] = np.inf
    inc = jnp.asarray(inc)
    got = min_update(cur, inc)
    want = ref.min_update_ref(cur, inc)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------- model (L2)


@pytest.mark.parametrize("n", [512, 1024])
def test_model_pagerank_step(n):
    rng = np.random.default_rng(n + 13)
    old, msg = rand(rng, n), rand(rng, n)
    deg = jnp.asarray(rng.integers(0, 20, size=n).astype(np.float32))
    new, contrib, dsum = model.pagerank_step(old, msg, deg)
    wnew, wcontrib, wdsum = ref.pagerank_step_ref(old, msg, deg)
    np.testing.assert_allclose(new, wnew, rtol=1e-6)
    np.testing.assert_allclose(contrib, wcontrib, rtol=1e-6)
    np.testing.assert_allclose(float(dsum), float(wdsum), rtol=1e-4)


@pytest.mark.parametrize("n", [512, 1024])
def test_model_min_step(n):
    rng = np.random.default_rng(n + 17)
    cur, inc = rand(rng, n, 0, 100), rand(rng, n, 0, 100)
    new, changed, count = model.min_step(cur, inc)
    wnew, wchanged, wcount = ref.min_step_ref(cur, inc)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(wnew))
    np.testing.assert_array_equal(np.asarray(changed), np.asarray(wchanged))
    assert float(count) == float(wcount)


def test_buckets_are_block_multiples():
    for b in model.BUCKETS:
        assert b % 512 == 0
    assert tuple(sorted(model.BUCKETS)) == model.BUCKETS
